"""Beyond-paper integration: MoE dispatch balance (the paper's Figs 11/13
translated to expert routing).

Three dispatch modes through the real front door
(``cluster.moe_dispatch``) under progressively skewed routers:
capacity (the Standard-Repartition-Join analogue — hot experts drop),
alpha_k (the dense StatJoin-planned layer) and cluster (tokens routed
through the instrumented exchange, per-expert counts taped).  Each row
reports the drop fraction over ALL routed assignments (tokens * top_k —
the denominator is the fanout, not a constant), the slot imbalance
(max/mean of the per-slot workload vector the report carries) and the
per-slot/per-expert k.  Results land in BENCH_moe.json; the skew-0.8
gate pins the paper's claim: the planned modes drop nothing and halve
the imbalance of capacity dispatch.
"""
from __future__ import annotations

import json
import os
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro import cluster
from repro.cluster.substrate import reset_default_pool
from repro.configs.base import MoEConfig
from repro.kernels import ops
from repro.models.moe import init_moe
from repro.obs import timeit
from repro.planner import clear_plan_cache

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_moe.json")

# Pallas dispatch budget for one cold cluster-routed dispatch (fresh
# pool).  The exchange body is the fused pair sort_kv (owner keys) +
# searchsorted (partition_sorted boundaries); the planner's sketch round
# that feeds plan_slots adds its sorted-runs pass (one sort + two
# searchsorted sweeps).  Anything above 5 means the token exchange or
# the sketch stopped riding the fused kernels.
MOE_DISPATCH_BUDGET = {"cluster": 5}


def _merge_bench_json(update: dict) -> None:
    data = {}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}
    data.update(update)
    with open(BENCH_JSON, "w") as f:
        json.dump(data, f, indent=2)


def _skewed_params(d: int, cfg: MoEConfig, skew: float):
    params = init_moe(jax.random.key(1), d, cfg, jnp.float32)
    router = np.array(params["router"]) * 0.02
    router[:, 0] += skew * np.linspace(0.2, 1.0, d)  # hot expert 0
    params["router"] = jnp.asarray(router)
    return params


def run(report_rows: List[str]) -> None:
    d, e, tokens = 64, 16, 8192
    t_machines, reps = 8, 5
    cfg = MoEConfig(num_experts=e, top_k=2, d_ff_expert=32,
                    capacity_factor=1.25, extra_slots=8)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(tokens, d)),
                    jnp.float32)
    assignments = tokens * cfg.top_k     # drop denominator = the fanout
    entries = []
    reset_default_pool()
    clear_plan_cache()

    for skew in (0.0, 0.3, 0.8):
        params = _skewed_params(d, cfg, skew)
        by_mode = {}
        for mode in ("capacity", "alpha_k", "cluster"):
            _, rep = cluster.moe_dispatch(params, x, cfg, mode=mode,
                                          t_machines=t_machines)
            # warm best-of timing (compiled programs + plan cache hot)
            best = timeit(
                lambda: cluster.moe_dispatch(
                    params, x, cfg, mode=mode, t_machines=t_machines)[0],
                reps=reps, warmup=0).best_us
            drop_pct = 100.0 * rep.total_dropped / assignments
            slot = np.asarray(rep.slot_workload, np.float64)
            imb = float(slot.max() / max(1.0, slot.mean()))
            by_mode[mode] = (drop_pct, imb)
            entries.append({
                "skew": skew, "mode": mode, "tokens": tokens,
                "top_k": cfg.top_k, "num_experts": e,
                "drop_pct": round(drop_pct, 3),
                "slot_imbalance": round(imb, 3),
                "k_slot": round(rep.k_slot, 4),
                "k_expert": round(rep.k_expert, 4),
                "alpha": rep.alpha,
                "expert_workload": np.asarray(rep.expert_workload,
                                              np.int64).tolist(),
                "best_us": round(best),
            })
            report_rows.append(
                f"moe_dispatch,skew={skew},{mode},"
                f"drop%={drop_pct:.2f},slot_imbalance={imb:.2f},"
                f"k_slot={rep.k_slot:.2f},us={best:.0f}")
        if skew == 0.8:
            # the paper's claim, pinned: planned dispatch drops nothing
            # and at least halves the capacity baseline's imbalance
            assert by_mode["capacity"][0] > 0, by_mode
            for mode in ("alpha_k", "cluster"):
                assert by_mode[mode][0] == 0.0, (mode, by_mode)
                assert by_mode[mode][1] * 2.0 <= by_mode["capacity"][1], (
                    f"{mode} imbalance {by_mode[mode][1]:.2f} not 2x below "
                    f"capacity {by_mode['capacity'][1]:.2f}")

    _merge_bench_json({
        "suite": "bench_moe_dispatch.run",
        "note": ("drop_pct is over tokens*top_k routed assignments; "
                 "slot_imbalance is max/mean of the per-slot workload "
                 "each report carries; cluster rows run the instrumented "
                 "exchange on the vmap substrate (CPU wall clock is a "
                 "correctness datapoint, not TPU performance), best of "
                 f"{reps} warm runs"),
        "entries": entries})
    report_rows.append(f"moe_dispatch,json,{os.path.abspath(BENCH_JSON)}")
    reset_default_pool()


def run_dispatch_budget(report_rows: List[str]) -> None:
    """Fusion contract for the cluster-routed dispatch: one cold query
    through ``cluster.moe_dispatch(mode="cluster")`` on the pallas
    kernel path must tick at most MOE_DISPATCH_BUDGET pallas dispatches
    (the fused sort_kv + boundary search of the token exchange)."""
    d, e, tokens = 32, 8, 512
    cfg = MoEConfig(num_experts=e, top_k=2, d_ff_expert=16, extra_slots=8)
    params = _skewed_params(d, cfg, 0.8)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(tokens, d)),
                    jnp.float32)
    reset_default_pool()
    clear_plan_cache()
    ops.reset_dispatch_counts()
    _, rep = cluster.moe_dispatch(params, x, cfg, mode="cluster",
                                  t_machines=4, kernel_backend="pallas")
    ticks = sum(c for (op, path), c in ops.DISPATCH_COUNTS.items()
                if path == "pallas")
    budget = MOE_DISPATCH_BUDGET["cluster"]
    report_rows.append(f"dispatch_budget,moe_cluster,ticks={ticks},"
                       f"budget={budget},ok={int(0 < ticks <= budget)}")
    assert 0 < ticks <= budget, (
        f"moe cluster dispatch: {ticks} pallas dispatches vs budget "
        f"{budget}: {dict(ops.DISPATCH_COUNTS)}")
    assert rep.total_dropped == 0
    reset_default_pool()
