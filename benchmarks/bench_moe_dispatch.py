"""Beyond-paper integration: MoE dispatch balance (the paper's Figs 11/13
translated to expert routing).  alpha_k (StatJoin-planned) vs capacity
dispatch under progressively skewed routers."""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.moe import init_moe, moe_layer


def run(report_rows: List[str]) -> None:
    d, e, tokens = 64, 16, 8192
    x = jnp.asarray(np.random.default_rng(0).normal(size=(tokens, d)),
                    jnp.float32)
    for skew in (0.0, 0.3, 0.8):
        for dispatch in ("capacity", "alpha_k"):
            cfg = MoEConfig(num_experts=e, top_k=2, d_ff_expert=32,
                            dispatch=dispatch, capacity_factor=1.25,
                            extra_slots=8)
            params = init_moe(jax.random.key(1), d, cfg, jnp.float32)
            router = np.asarray(params["router"]) * 0.02
            router[:, 0] += skew * np.linspace(0.2, 1.0, d)  # hot expert
            params["router"] = jnp.asarray(router)
            fn = jax.jit(lambda p, xx: moe_layer(p, xx, cfg))
            _, stats = fn(params, x)  # warm + run
            t0 = time.time()
            _, stats = jax.block_until_ready(fn(params, x))
            dt = time.time() - t0
            drop_pct = 100 * float(stats.dropped) / (tokens * 2)
            imb = float(stats.max_slot_load) / max(
                1.0, float(stats.mean_slot_load))
            report_rows.append(
                f"moe_dispatch,skew={skew},{dispatch},"
                f"drop%={drop_pct:.2f},slot_imbalance={imb:.2f},"
                f"us={dt*1e6:.0f}")
