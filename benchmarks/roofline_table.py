"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
records written by repro.launch.dryrun.

    PYTHONPATH=src python -m benchmarks.roofline_table [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def load(dirname):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | compile | args/dev | "
            "temp/dev | fits 16GiB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("variant"):
            continue
        mem = r.get("memory_per_device", {})
        rows.append(
            "| {arch} | {shape} | {mesh} | {status} | {c} | {a} | {t} | "
            "{f} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                status=r.get("status", "?"),
                c=f"{r.get('compile_s', '-')}s" if "compile_s" in r else "-",
                a=fmt_bytes(mem.get("arguments_bytes")),
                t=fmt_bytes(mem.get("temp_bytes")),
                f={True: "yes", False: "NO"}.get(
                    mem.get("fits_16GiB_hbm"), "-")))
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | T_comp | T_mem | T_coll | dominant | "
            "useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != "single" or "roofline" not in r or r.get("variant"):
            continue
        rf = r["roofline"]
        rows.append(
            "| {arch} | {shape} | {tc} | {tm} | {tl} | **{dom}** | "
            "{ur:.2f} | {frac:.3f} |".format(
                arch=r["arch"], shape=r["shape"],
                tc=fmt_s(rf["t_compute_s"]), tm=fmt_s(rf["t_memory_s"]),
                tl=fmt_s(rf["t_collective_s"]), dom=rf["dominant"],
                ur=rf["useful_flop_ratio"],
                frac=rf["roofline_fraction"]))
    return "\n".join(rows)


def exchange_table(bench):
    """Per-stage network bytes of the sort exchange, expected vs achieved.

    Expected comes from the roofline exchange model (the same buffer
    arithmetic the runtime allocates); achieved is what
    bench_sort.run_exchange_compare measured.  The per-stage rows are
    what the flat columns of the kernel table cannot show: the staged
    topology trades one t-fan-in hop for two sqrt(t) hops, and the bytes
    column is where that shows up.
    """
    ec = bench.get("exchange_compare")
    if not ec:
        return ""
    from repro.launch.roofline import exchange_stage_bytes
    rows = ["| t | topology | stage | fan-in | expected recv/shard | "
            "measured peak | retries | wall |",
            "|---|---|---|---|---|---|---|---|"]
    for e in ec.get("entries", []):
        for topo in ("flat", "staged"):
            stages = exchange_stage_bytes(
                e["t"], e["m"], topology=topo,
                cap_factor=e[f"{topo}_cap_factor"])
            peak = max(s.receive_bytes for s in stages)
            for i, s in enumerate(stages):
                first = i == 0
                rows.append(
                    "| {t} | {topo} | {st} | {f} | {exp} | {meas} | {ret} "
                    "| {wall} |".format(
                        t=e["t"] if first and topo == "flat" else "",
                        topo=topo if first else "",
                        st=s.name, f=s.fanin,
                        exp=fmt_bytes(s.receive_bytes),
                        meas=(fmt_bytes(e[f"{topo}_peak_receive_bytes"])
                              + ("" if peak ==
                                 e[f"{topo}_peak_receive_bytes"]
                                 else " (!)")) if first else "",
                        ret=(e[f"{topo}_capacity_attempts"] - 1)
                        if first else "",
                        wall=fmt_s(e[f"{topo}_us"] * 1e-6)
                        if first else ""))
    return "\n".join(rows)


def skips_table(recs):
    rows = []
    for r in recs:
        if r.get("status") == "skip":
            rows.append(f"* {r['arch']} x {r['shape']} ({r['mesh']}): "
                        f"{r['skip_reason']}")
    return "\n".join(rows)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--bench-sort", default="BENCH_sort.json",
                   help="BENCH_sort.json with an exchange_compare record")
    args = p.parse_args()
    recs = load(args.dir)
    ok = sum(1 for r in recs if r.get("status") == "ok")
    skip = sum(1 for r in recs if r.get("status") == "skip")
    err = sum(1 for r in recs if r.get("status") == "error")
    print(f"## Dry-run summary: {ok} ok, {skip} documented skips, "
          f"{err} errors\n")
    print(dryrun_table(recs))
    print("\n### Skips\n")
    print(skips_table(recs))
    print("\n## Roofline (single-pod, per device)\n")
    print(roofline_table(recs))
    if os.path.exists(args.bench_sort):
        with open(args.bench_sort) as f:
            table = exchange_table(json.load(f))
        if table:
            print("\n## Exchange network bytes (per shard, per stage)\n")
            print(table)


if __name__ == "__main__":
    main()
