"""Traced-query report: one warm ``engine.submit`` rendered as a span
tree, with the exchange phases joined against the roofline model.

Two suites:

* :func:`run` — a warm engine executes one traced SMMS sort per exchange
  topology (flat and staged).  For each query it renders the span tree,
  reconciles every ``phase:*`` leaf span bitwise against the
  ``AlphaKReport`` the same execution returned (both views are the same
  bound tape snapshot, so anything but equality is a plumbing bug),
  joins the shuffle phases against ``exchange_stage_bytes`` — the static
  receive buffer the roofline model predicts vs the bytes the tape
  actually received — and dumps the trace as Chrome-trace JSON
  (TRACE_query.json, loadable in ``chrome://tracing`` / Perfetto).

* :func:`run_overhead_gate` — the tracing-off contract: with the tracer
  disabled a warm query records zero traces and module-level ``span()``
  costs one ContextVar read, so the warm per-query time must not exceed
  the traced time by more than the noise bound asserted here.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

import numpy as np
import jax.numpy as jnp

from repro.cluster import SubstratePool
from repro.data import uniform_keys
from repro.launch.roofline import exchange_stage_bytes
from repro.obs import Tracer, chrome_trace, timeit, write_chrome_trace
from repro.serve import QueryEngine, sort_query
from repro.serve.query import run_spec

TRACE_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "TRACE_query.json")

BYTES_PER_OBJ = 4   # int32 keys — what the exchange actually moves


def _phase_spans(root) -> List:
    """The ``phase:*`` leaf spans of a query trace, in execution order."""
    return [s for s in root.walk() if s.name.startswith("phase:")]


def reconcile(root, report) -> None:
    """Assert the span tree's phase leaves ARE the report's taped phases.

    Bitwise: both come from the same ``bound_snapshot``, so names, order
    and every per-machine sent/received count must match exactly.
    """
    spans = _phase_spans(root)
    assert [s.name for s in spans] == [
        f"phase:{p.name}" for p in report.phases], (
        [s.name for s in spans], [p.name for p in report.phases])
    for sp, ph in zip(spans, report.phases):
        assert np.array_equal(np.asarray(sp.attrs["sent"]),
                              np.asarray(ph.sent)), sp.name
        assert np.array_equal(np.asarray(sp.attrs["received"]),
                              np.asarray(ph.received)), sp.name


def exchange_rows(root, report, m: int, *,
                  overlap_chunks: int = 2) -> List[dict]:
    """Join shuffle phase spans against the roofline exchange model.

    Expected is the static per-shard receive buffer
    (``exchange_stage_bytes`` — the same arithmetic the runtime
    allocates); achieved is the peak per-shard bytes the tape recorded.
    Achieved can never exceed expected (the buffer IS the capacity);
    the fill fraction is how much of the provisioned roofline the
    actual skew used.
    """
    topology = getattr(report, "exchange_topology", "flat") or "flat"
    stages = exchange_stage_bytes(
        report.t, m, topology=topology, cap_factor=report.cap_factor,
        bytes_per_obj=BYTES_PER_OBJ, overlap_chunks=overlap_chunks)
    shuffle = [s for s in _phase_spans(root) if "shuffle" in s.name]
    assert len(shuffle) == len(stages), (
        [s.name for s in shuffle], [s.name for s in stages])
    rows = []
    for sp, st in zip(shuffle, stages):
        achieved = int(np.max(np.asarray(sp.attrs["received"]))
                       ) * BYTES_PER_OBJ
        assert achieved <= st.receive_bytes, (sp.name, achieved, st)
        rows.append({
            "phase": sp.name, "stage": st.name, "fanin": st.fanin,
            "expected_recv_bytes": int(st.receive_bytes),
            "achieved_recv_bytes": achieved,
            "fill": round(achieved / st.receive_bytes, 4),
        })
    return rows


def _traced_query(t: int, m: int, exchange: str, pool, tracer):
    """One warm traced submit: pool/plan caches are hot, the LRU is not.

    The engine's result cache would satisfy a repeat of the warming
    query without executing (trace=None by design), so warming goes
    through ``run_spec`` directly on the shared pool and the engine sees
    the spec exactly once.
    """
    x = jnp.asarray(uniform_keys(t * m, seed=7).reshape(t, m))
    spec = sort_query(x, algorithm="smms", exchange=exchange)
    run_spec(spec, substrate=pool)      # warm compile + plan caches
    engine = QueryEngine(pool=pool, tracer=tracer)
    try:
        res = engine.run([spec])[0]
    finally:
        engine.close()
    assert res.ok, res.error
    assert res.trace is not None and res.trace_id == res.trace.trace_id
    return res, res.report      # the same execution's taped report


def run(report_rows: List[str]) -> None:
    t, m = 8, 256
    pool = SubstratePool()
    tracer = Tracer(enabled=True)
    payload = {}
    for exchange in ("flat", "staged"):
        res, report = _traced_query(t, m, exchange, pool, tracer)
        root = res.trace
        reconcile(root, report)
        rows = exchange_rows(root, report, m)
        payload[exchange] = {
            "trace_id": res.trace_id,
            "tree": root.tree_str(),
            "exchange": rows,
        }
        compiles = sum(1 for s in root.walk()
                       for e in s.events if e.name == "compile")
        assert compiles == 0, root.tree_str()   # warm means warm
        for r in rows:
            report_rows.append(
                f"trace_report,{exchange},{r['stage']},fanin={r['fanin']},"
                f"expected={r['expected_recv_bytes']},"
                f"achieved={r['achieved_recv_bytes']},fill={r['fill']}")
    # ---- Chrome trace: both topologies' traces in one file ----------------
    traces = list(tracer.traces)
    doc = chrome_trace(traces)
    assert doc["traceEvents"], doc
    json.loads(json.dumps(doc))         # valid, serializable JSON
    write_chrome_trace(TRACE_JSON, traces)
    report_rows.append(f"trace_report,json,{os.path.abspath(TRACE_JSON)}")
    report_rows.append(
        "trace_report,tree,flat:\n" + payload["flat"]["tree"])


def run_overhead_gate(report_rows: List[str]) -> None:
    """Tracing off must cost nothing: zero traces recorded, and the warm
    per-query wall time within noise of the traced run."""
    t, m = 8, 256
    x = jnp.asarray(uniform_keys(t * m, seed=11).reshape(t, m))
    spec = sort_query(x, algorithm="smms")
    pool = SubstratePool()
    run_spec(spec, substrate=pool)      # warm

    def _run_with(tracer: Optional[Tracer]):
        engine = QueryEngine(pool=pool, tracer=tracer,
                             result_cache_size=0)
        try:
            return timeit(lambda: engine.run([spec])[0],
                          reps=5, warmup=1)
        finally:
            engine.close()

    off_tracer = Tracer(enabled=False)
    off = _run_with(off_tracer)
    assert off.last_result.trace is None
    assert not off_tracer.traces, "disabled tracer recorded spans"

    on_tracer = Tracer(enabled=True)
    on = _run_with(on_tracer)
    assert on.last_result.trace is not None

    ratio = off.best_s / on.best_s
    report_rows.append(
        f"trace_overhead,off_us={off.best_us:.0f},on_us={on.best_us:.0f},"
        f"off_over_on={ratio:.3f}")
    # off-mode work is a strict subset of on-mode work; 1.25x covers
    # scheduler noise on a shared CI box without masking a real leak
    # (an accidentally-always-on tracer shows up as ratio ~1.0 plus
    # recorded traces, caught by the zero-traces assert above).
    assert ratio <= 1.25, (off.best_us, on.best_us)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)
    run_overhead_gate(rows)
    print("\n".join(rows))
