"""Benchmark harness — one module per paper table/figure.

Prints ``name,...,derived`` CSV rows.  Every row corresponds to a paper
table/figure (see DESIGN.md §12) or a beyond-paper integration measurement.
Assertions inside the benches enforce the paper's claims (SMMS balance,
Theorem 6 bound, statistics-collection overhead, ...).
"""
from __future__ import annotations

import sys
import time
from typing import List


def main() -> None:
    from benchmarks import (bench_alpha_k, bench_join, bench_kernels,
                            bench_moe_dispatch, bench_serve, bench_sort,
                            trace_report)

    rows: List[str] = []
    suites = [
        ("Figs 8-10: sort imbalance+runtime", bench_sort.run),
        ("Table 1: sort scaling", bench_sort.run_scaling),
        ("Kernel dispatch on/off -> BENCH_sort.json",
         bench_sort.run_kernel_compare),
        ("Fusion dispatch-count budget", bench_sort.run_dispatch_budget),
        ("Figs 11-14: join balance+runtime", bench_join.run),
        ("Tables 2-3/Fig 15: StatJoin stats overhead",
         bench_join.run_statjoin_overhead),
        ("Thms 1/2/3/6: alpha-k verification", bench_alpha_k.run),
        ("MoE dispatch (beyond-paper) -> BENCH_moe.json",
         bench_moe_dispatch.run),
        ("MoE cluster dispatch-count budget",
         bench_moe_dispatch.run_dispatch_budget),
        ("Pallas kernels", bench_kernels.run),
        ("Serving engine vs one-shot -> BENCH_serve.json",
         bench_serve.run),
        ("Traced query + roofline join -> TRACE_query.json",
         trace_report.run),
        ("Tracing-off overhead gate", trace_report.run_overhead_gate),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        mark = len(rows)
        try:
            fn(rows)
        except Exception as exc:  # keep the harness going, report at end
            failures.append((name, repr(exc)))
            rows.append(f"SUITE_FAILED,{name},{exc!r}")
        for row in rows[mark:]:
            print(row, flush=True)
        print(f"# ({time.time() - t0:.1f}s)", flush=True)

    print(f"# total rows: {len(rows)}")
    if failures:
        print("# FAILURES:", failures)
        sys.exit(1)
    print("# ALL BENCHMARK SUITES PASSED")


if __name__ == "__main__":
    main()
